"""Distributed execution layer (repro.federated.dist) coverage.

The layer's contract:
  * ``make_host_mesh`` raises ``ValueError`` (not a stripped assert) on
    indivisible factorizations, and builds the 3-axis ("pod", "data",
    "model") layout on simulated host devices;
  * ``DistConfig`` owns the merge|psum validation and axis resolution the
    engines used to triplicate;
  * ``two_stage_psum`` (one psum per axis, innermost first) equals the flat
    all-reduce;
  * all FOUR engines route their psum backend through the dist layer: with
    ``DistConfig(mesh=...)`` each host call is ONE shard_map dispatch whose
    results match the single-device ``merge`` backend — bitwise for A/b (and
    the factored L/W downstream) on grid-quantized features where fp32
    sums are exact, ≤ 1e-5 for solved classifiers in general;
  * shard-count invariance: the same packed arrays give the same A, b, L, W
    at data-parallel 1 and data-parallel N;
  * the packers' ``mesh``/``num_shards`` padding adds only fully-masked
    blocks — exact no-ops that leave every engine's output bit-identical.

Most sharded tests need ≥ 4 simulated devices:
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the multi-device
CI job sets this); on 1 device they skip, while the mesh-mode plumbing
tests still run (a 1-device mesh is a valid degenerate case).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fed3r
from repro.data.pipeline import (
    pack_arrival_waves,
    pack_client_shards,
    pack_cohort_batches,
    pack_personal_cohort,
)
from repro.federated.algorithms import make_algorithm
from repro.federated.dist import DistConfig, DistContext, two_stage_psum
from repro.federated.engine import AccumulationEngine, EngineConfig
from repro.federated.personalization import (
    PersonalizationEngine,
    PersonalizeConfig,
)
from repro.federated.round_engine import RoundConfig, RoundEngine
from repro.federated.streaming_engine import StreamConfig, StreamingEngine
from repro.launch.mesh import (
    data_axes,
    data_parallel_size,
    make_host_mesh,
)

D, C = 16, 5
LAM = 0.1

N_DEV = len(jax.devices())
needs4 = pytest.mark.skipif(
    N_DEV < 4,
    reason="needs >=4 simulated devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)


def _grid_clients(seed, sizes, d=D, n_classes=C):
    """Clients whose features live on a 1/8 grid in [-2, 2]: all Gram
    products land on a 1/64 grid and every partial sum stays far below
    2^24/64, so fp32 accumulation is EXACT — any summation order (scan
    fold, psum tree, two-stage hierarchy) produces bit-identical A/b."""
    rng = np.random.default_rng(seed)
    return [
        (
            (rng.integers(-16, 17, size=(n, d)) / 8.0).astype(np.float32),
            rng.integers(0, n_classes, size=n).astype(np.int32),
        )
        for n in sizes
    ]


def _submesh(dp: int) -> jax.sharding.Mesh:
    """A (data=dp, model=1) mesh over the first dp local devices."""
    devs = np.asarray(jax.devices()[:dp]).reshape(dp, 1)
    return jax.sharding.Mesh(devs, ("data", "model"))


def _psum_cfg(mesh, **kw) -> DistConfig:
    return DistConfig(aggregation="psum", mesh=mesh, donate=False, **kw)


# ---------------------------------------------------------------------------
# host meshes
# ---------------------------------------------------------------------------


def test_make_host_mesh_raises_on_indivisible():
    with pytest.raises(ValueError):
        make_host_mesh(model_parallel=N_DEV + 1)
    with pytest.raises(ValueError):
        make_host_mesh(model_parallel=0)
    with pytest.raises(ValueError):
        make_host_mesh(pods=0)
    with pytest.raises(ValueError):
        make_host_mesh(pods=N_DEV + 1)


def test_make_host_mesh_axis_layouts():
    mesh = make_host_mesh()
    assert mesh.axis_names == ("data", "model")
    assert data_axes(mesh) == ("data",)
    assert data_parallel_size(mesh) == N_DEV


@needs4
def test_make_host_mesh_pod_variant_is_three_axis():
    mesh = make_host_mesh(pods=2)
    assert mesh.axis_names == ("pod", "data", "model")
    assert data_axes(mesh) == ("pod", "data")
    assert mesh.devices.shape == (2, N_DEV // 2, 1)
    assert data_parallel_size(mesh) == N_DEV


# ---------------------------------------------------------------------------
# DistConfig / DistContext
# ---------------------------------------------------------------------------


def test_dist_config_validation():
    with pytest.raises(ValueError):
        DistConfig(aggregation="allgather")
    with pytest.raises(ValueError):
        DistConfig(aggregation="psum")  # no axes, no mesh
    with pytest.raises(ValueError):
        DistConfig(aggregation="merge", mesh=make_host_mesh())  # merge is local
    with pytest.raises(ValueError):
        DistConfig(
            aggregation="psum", mesh=make_host_mesh(), mesh_axes=("nonexistent",)
        )
    # explicit axes without a mesh: the external-shard_map contract
    cfg = DistConfig(aggregation="psum", mesh_axes=("data",))
    assert cfg.axis_names == ("data",)
    assert cfg.data_shards == 1


def test_dist_config_resolves_axes_from_mesh():
    mesh = make_host_mesh()
    cfg = DistConfig(aggregation="psum", mesh=mesh)
    assert cfg.axis_names == ("data",)
    assert cfg.data_shards == N_DEV


def test_dist_context_merge_all_reduce_is_identity():
    ctx = DistContext(DistConfig())
    tree = {"a": jnp.ones((3,))}
    assert ctx.all_reduce(tree) is tree
    ctx.dispatch()
    ctx.dispatch()
    assert ctx.dispatches == 2


@needs4
def test_two_stage_psum_equals_flat_psum_on_pod_mesh():
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = make_host_mesh(pods=2)
    dp = data_parallel_size(mesh)
    x = jnp.asarray(
        (np.random.default_rng(0).integers(-16, 17, size=(dp, 8)) / 8.0
         ).astype(np.float32)
    )

    def two_stage(v):
        return two_stage_psum(v, ("pod", "data"))

    def flat(v):
        return jax.lax.psum(v, ("pod", "data"))

    spec = P(("pod", "data"))
    a = shard_map(two_stage, mesh=mesh, in_specs=spec, out_specs=P())(x)
    b = shard_map(flat, mesh=mesh, in_specs=spec, out_specs=P())(x)
    # exact grid values: any reduction order is bit-identical
    assert np.array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_allclose(
        np.asarray(a).reshape(-1), np.asarray(x).sum(0)
    )


# ---------------------------------------------------------------------------
# packer dp-padding: fully-masked blocks are exact no-ops
# ---------------------------------------------------------------------------


def test_pack_client_shards_dp_padding_is_bitwise_noop():
    clients = _grid_clients(0, [5, 9, 2, 7, 3])
    plain = pack_client_shards(clients, 2, max_n=16)
    padded = pack_client_shards(clients, 2, max_n=16, num_shards=4)
    assert padded.n_shards % 4 == 0
    assert padded.n_clients == plain.n_clients
    eng = AccumulationEngine(EngineConfig(n_classes=C))
    a = eng.accumulate(eng.init(D), plain)
    b = eng.accumulate(eng.init(D), padded)
    assert np.array_equal(np.asarray(a.stats.A), np.asarray(b.stats.A))
    assert np.array_equal(np.asarray(a.stats.b), np.asarray(b.stats.b))
    assert np.array_equal(np.asarray(a.class_counts), np.asarray(b.class_counts))


def test_pack_arrival_waves_dp_padding_is_bitwise_noop():
    waves = [_grid_clients(t, [6] * (1 + t % 3)) for t in range(4)]
    plain = pack_arrival_waves(waves)
    padded = pack_arrival_waves(waves, num_shards=4)
    assert padded.clients_per_wave % 4 == 0
    eng = StreamingEngine(StreamConfig(n_classes=C, ridge_lambda=LAM))
    sa, _ = eng.absorb(eng.init(D), plain)
    sb, _ = eng.absorb(eng.init(D), padded)
    assert np.array_equal(np.asarray(sa.L), np.asarray(sb.L))
    assert np.array_equal(np.asarray(sa.W), np.asarray(sb.W))


def test_pack_cohort_batches_dp_padding_is_noop():
    clients = _grid_clients(1, [20, 12, 17])
    plain = pack_cohort_batches(clients, 8, 3)
    padded = pack_cohort_batches(clients, 8, 3, num_shards=4)
    assert padded.cohort % 4 == 0 and padded.n_clients == 3
    params0 = {"W": jnp.zeros((D, C), jnp.float32)}
    freeze = jax.tree.map(lambda _: 1.0, params0)

    def loss(params, batch):
        logits = batch["x"] @ params["W"]
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(
            logits, batch["y"][:, None].astype(jnp.int32), axis=-1
        )[:, 0]
        return lse - picked

    rc = RoundConfig(algo=make_algorithm("fedavg"), client_lr=0.1,
                     n_total_clients=3)
    eng = RoundEngine(rc, loss, freeze)
    sa = eng.step(eng.init(params0), plain)
    sb = eng.step(eng.init(params0), padded)
    np.testing.assert_allclose(
        np.asarray(sa.params["W"]), np.asarray(sb.params["W"]),
        rtol=0, atol=1e-7,
    )


def test_pack_personal_cohort_dp_padding_is_noop():
    clients = _grid_clients(2, [12, 9, 15])
    plain = pack_personal_cohort(clients, holdout_frac=0.25)
    padded = pack_personal_cohort(clients, holdout_frac=0.25, num_shards=4)
    assert padded.cohort % 4 == 0 and padded.n_clients == 3
    fac = _factored_state(clients)
    eng = PersonalizationEngine(PersonalizeConfig(n_classes=C))
    ha = eng.solve_heads(fac, plain)
    hb = eng.solve_heads(fac, padded)
    real = np.asarray(padded.client_ids) >= 0
    assert np.array_equal(np.asarray(ha.alpha), np.asarray(hb.alpha)[real])
    np.testing.assert_allclose(
        np.asarray(ha.W), np.asarray(hb.W)[real], rtol=0, atol=1e-6
    )


def _factored_state(clients) -> fed3r.Fed3RFactored:
    fac = fed3r.init_factored(D, C, LAM)
    return fed3r.factored_update(
        fac,
        jnp.asarray(np.concatenate([x for x, _ in clients])),
        jnp.asarray(np.concatenate([y for _, y in clients])),
    )


# ---------------------------------------------------------------------------
# four-engine psum == merge on the sharded host mesh (ONE dispatch each)
# ---------------------------------------------------------------------------


@needs4
def test_accumulation_engine_sharded_matches_merge_bitwise():
    mesh = make_host_mesh()
    clients = _grid_clients(3, [9, 3, 14, 6, 1, 11, 8, 4])
    packed = pack_client_shards(clients, 2, max_n=16, mesh=mesh)

    merge_eng = AccumulationEngine(EngineConfig(n_classes=C))
    ref = merge_eng.accumulate(merge_eng.init(D), packed)

    eng = AccumulationEngine(EngineConfig(n_classes=C, dist=_psum_cfg(mesh)))
    acc = eng.accumulate(eng.init(D), packed)
    assert eng.dispatches == 1  # the whole sharded fold is ONE dispatch
    # exact grid features: the psum tree cannot change a bit of A or b
    assert np.array_equal(np.asarray(ref.stats.A), np.asarray(acc.stats.A))
    assert np.array_equal(np.asarray(ref.stats.b), np.asarray(acc.stats.b))
    assert np.array_equal(
        np.asarray(ref.class_counts), np.asarray(acc.class_counts)
    )
    # and the solved classifier agrees within fp32 solve tolerance
    W_ref = fed3r.solve(ref.stats, LAM)
    W_got = fed3r.solve(acc.stats, LAM)
    np.testing.assert_allclose(
        np.asarray(W_ref), np.asarray(W_got), rtol=0, atol=1e-5
    )


@needs4
def test_streaming_engine_sharded_matches_merge_bitwise():
    mesh = make_host_mesh()
    waves = [_grid_clients(10 + t, [8] * (2 + t % 2)) for t in range(5)]
    packed = pack_arrival_waves(waves, mesh=mesh)

    merge_eng = StreamingEngine(StreamConfig(n_classes=C, ridge_lambda=LAM))
    ref, _ = merge_eng.absorb(merge_eng.init(D), packed)

    eng = StreamingEngine(
        StreamConfig(n_classes=C, ridge_lambda=LAM, dist=_psum_cfg(mesh))
    )
    got, trace = eng.absorb(eng.init(D), packed)
    assert eng.dispatches == 1
    # exact per-wave Grams ⇒ identical refactorizations ⇒ bitwise L and W
    assert np.array_equal(np.asarray(ref.L), np.asarray(got.L))
    assert np.array_equal(np.asarray(ref.W), np.asarray(got.W))
    assert float(got.n) == float(ref.n)
    assert np.asarray(trace.refreshed).all()


@needs4
def test_round_engine_sharded_matches_merge():
    mesh = make_host_mesh()
    clients = _grid_clients(4, [24, 18, 30, 12])
    cohort = pack_cohort_batches(clients, 8, 3, mesh=mesh)
    params0 = {"W": jnp.zeros((D, C), jnp.float32)}
    freeze = jax.tree.map(lambda _: 1.0, params0)

    def loss(params, batch):
        logits = batch["x"] @ params["W"]
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(
            logits, batch["y"][:, None].astype(jnp.int32), axis=-1
        )[:, 0]
        return lse - picked

    def rc(dist):
        return RoundConfig(algo=make_algorithm("fedavg"), client_lr=0.1,
                           n_total_clients=4, dist=dist)

    merge_eng = RoundEngine(rc(DistConfig()), loss, freeze)
    ref = merge_eng.step(merge_eng.init(params0), cohort)

    eng = RoundEngine(rc(_psum_cfg(mesh)), loss, freeze)
    got = eng.step(eng.init(params0), cohort)
    assert eng.dispatches == 1
    np.testing.assert_allclose(
        np.asarray(ref.params["W"]), np.asarray(got.params["W"]),
        rtol=1e-5, atol=1e-6,
    )


@needs4
def test_personalization_engine_sharded_matches_merge():
    mesh = make_host_mesh()
    # strongly label-skewed tenants so the α sweep's score gaps dwarf any
    # batched-solve ulp differences between local cohort widths
    rng = np.random.default_rng(5)
    clients = []
    for k in range(8):
        n = 12
        feats = (rng.integers(-16, 17, size=(n, D)) / 8.0).astype(np.float32)
        labels = np.full((n,), k % C, dtype=np.int32)
        clients.append((feats, labels))
    packed = pack_personal_cohort(clients, mesh=mesh)
    fac = _factored_state(clients)

    merge_eng = PersonalizationEngine(PersonalizeConfig(n_classes=C))
    ref = merge_eng.solve_heads(fac, packed)

    eng = PersonalizationEngine(
        PersonalizeConfig(n_classes=C, dist=_psum_cfg(mesh))
    )
    got = eng.solve_heads(fac, packed)
    assert eng.dispatches == 1
    assert np.array_equal(np.asarray(ref.alpha), np.asarray(got.alpha))
    np.testing.assert_allclose(
        np.asarray(ref.W), np.asarray(got.W), rtol=0, atol=1e-5
    )
    # fixed-α path too (the serving cache re-solve shape)
    at_ref = merge_eng.solve_at(fac, packed, ref.alpha)
    at_got = eng.solve_at(fac, packed, ref.alpha)
    np.testing.assert_allclose(
        np.asarray(at_ref.W), np.asarray(at_got.W), rtol=0, atol=1e-5
    )


# ---------------------------------------------------------------------------
# shard-count invariance: data-parallel 1 vs 4 on the SAME packed arrays
# ---------------------------------------------------------------------------


@needs4
def test_shard_count_invariance_stats_and_stream():
    clients = _grid_clients(6, [7, 13, 5, 9, 11, 3, 8, 6])
    packed = pack_client_shards(clients, 2, max_n=16, num_shards=4)
    waves = [_grid_clients(20 + t, [8] * 4) for t in range(3)]
    arrivals = pack_arrival_waves(waves, num_shards=4)

    results = {}
    for dp in (1, 4):
        mesh = _submesh(dp)
        eng = AccumulationEngine(EngineConfig(n_classes=C, dist=_psum_cfg(mesh)))
        acc = eng.accumulate(eng.init(D), packed)
        s_eng = StreamingEngine(
            StreamConfig(n_classes=C, ridge_lambda=LAM, dist=_psum_cfg(mesh))
        )
        st, _ = s_eng.absorb(s_eng.init(D), arrivals)
        results[dp] = (acc, st)

    a1, s1 = results[1]
    a4, s4 = results[4]
    # same A, b, L, W at data-parallel 1 vs 4 — bitwise on the exact grid
    assert np.array_equal(np.asarray(a1.stats.A), np.asarray(a4.stats.A))
    assert np.array_equal(np.asarray(a1.stats.b), np.asarray(a4.stats.b))
    assert np.array_equal(np.asarray(s1.L), np.asarray(s4.L))
    assert np.array_equal(np.asarray(s1.W), np.asarray(s4.W))
    W1 = fed3r.solve(a1.stats, LAM)
    W4 = fed3r.solve(a4.stats, LAM)
    np.testing.assert_allclose(np.asarray(W1), np.asarray(W4), rtol=0, atol=1e-5)


@needs4
def test_streaming_sharded_on_pod_mesh():
    """The 3-axis ("pod", "data", "model") host mesh end to end: the wave
    statistics reduce intra-pod then cross-pod and still match merge."""
    mesh = make_host_mesh(pods=2)
    waves = [_grid_clients(30 + t, [8] * 4) for t in range(3)]
    packed = pack_arrival_waves(waves, mesh=mesh)

    merge_eng = StreamingEngine(StreamConfig(n_classes=C, ridge_lambda=LAM))
    ref, _ = merge_eng.absorb(merge_eng.init(D), packed)

    eng = StreamingEngine(
        StreamConfig(n_classes=C, ridge_lambda=LAM, dist=_psum_cfg(mesh))
    )
    got, _ = eng.absorb(eng.init(D), packed)
    assert eng.dispatches == 1
    assert np.array_equal(np.asarray(ref.L), np.asarray(got.L))
    assert np.array_equal(np.asarray(ref.W), np.asarray(got.W))
