"""End-to-end system behaviour: the paper's pipeline on a real backbone.

FED3R with a transformer feature extractor φ (reduced config), exercising
the full statistics → aggregation → solve → FT-init path, plus the
distributed-runtime statistics step on a host mesh (psum aggregation
equivalence — the datacenter code path at test scale).
"""
import jax
import jax.numpy as jnp
import numpy as np

from conftest import make_batch
from repro.configs import get_config
from repro.core import calibration, fed3r
from repro.data.synthetic import make_token_dataset
from repro.launch.steps import make_fed3r_stats_step
from repro.models import build_model


def test_fed3r_on_transformer_features(rng):
    """Statistics over a real backbone's pooled features → working classifier."""
    cfg = get_config("fed3r-mnv2-proxy-smoke").replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(rng)
    C = 8
    ds = make_token_dataset(jax.random.PRNGKey(1), 256, 16, cfg.vocab_size, C)

    extract = jax.jit(lambda b: model.extract_features(params, b))
    # split "clients" = batches; aggregate statistics exactly
    stats = fed3r.init_stats(cfg.d_feat, C)
    for s in range(0, 256, 64):
        feats = extract({"tokens": ds.tokens[s : s + 64]})
        stats = fed3r.merge(
            stats, fed3r.client_stats(feats, ds.labels[s : s + 64], C)
        )
    W = fed3r.solve(stats, 0.01)

    # centralized equivalence
    feats_all = extract({"tokens": ds.tokens})
    W_cen = fed3r.solve(fed3r.client_stats(feats_all, ds.labels, C), 0.01)
    np.testing.assert_allclose(np.asarray(W), np.asarray(W_cen), rtol=1e-3, atol=1e-3)

    # the class-prefix token makes features informative → above chance
    acc = float(fed3r.accuracy(W, feats_all, ds.labels))
    assert acc > 2.0 / C, acc

    # calibrated softmax init is finite
    temp, _ = calibration.calibrate_temperature(
        fed3r.predict(W, feats_all), ds.labels
    )
    W_init = calibration.fold_temperature(W, temp)
    assert bool(jnp.all(jnp.isfinite(W_init)))


def test_fed3r_stats_step_matches_simulator_path(rng):
    """launch.steps.make_fed3r_stats_step == core path (same batch)."""
    cfg = get_config("qwen2-7b-smoke").replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(rng)
    C = 5
    batch = make_batch(cfg, rng, 4, 16, with_labels=False)
    batch["class_labels"] = jax.random.randint(jax.random.fold_in(rng, 3), (4,), 0, C)

    step = jax.jit(make_fed3r_stats_step(cfg, C))
    stats0 = fed3r.init_stats(cfg.d_feat, C)
    stats1 = step(params, stats0, batch)

    feats = model.extract_features(params, batch)
    ref = fed3r.client_stats(feats, batch["class_labels"], C)
    np.testing.assert_allclose(np.asarray(stats1.A), np.asarray(ref.A),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(stats1.b), np.asarray(ref.b),
                               rtol=1e-4, atol=1e-4)
    assert float(stats1.n) == 4.0


def test_fed3r_psum_aggregation_on_host_mesh(rng):
    """The datacenter aggregation (psum over data) == simulator merge."""
    from repro.core.fed3r import aggregate_mesh
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev,), ("data",))
    d, C, n = 8, 3, 4 * n_dev
    feats = jax.random.normal(rng, (n, d))
    labels = jax.random.randint(jax.random.fold_in(rng, 1), (n,), 0, C)

    def local_stats(f, l):
        s = fed3r.client_stats(f, l, C)
        return aggregate_mesh(s, ("data",))

    agg = shard_map(
        local_stats, mesh=mesh,
        in_specs=(P("data", None), P("data")),
        out_specs=P(),
    )(feats, labels)
    ref = fed3r.client_stats(feats, labels, C)
    np.testing.assert_allclose(np.asarray(agg.A), np.asarray(ref.A),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(agg.b), np.asarray(ref.b),
                               rtol=1e-5, atol=1e-5)
