"""Batched cohort round engine: parity, invariance, hot-path purity, resume.

The engine's contract (federated/round_engine.py):
  * packed one-dispatch ``round_step`` == the per-client reference loop for
    fedavg / fedprox / scaffold (same local-update math, same pure server
    transition), within fp tolerance;
  * freeze-mask semantics of the FT strategies: frozen subtrees are
    BIT-identical after rounds, trainable subtrees move;
  * the aggregated round is bitwise invariant to cohort sampling order
    (canonical cohort packing + per-(seed, client) shuffling);
  * the round hot path performs NO host transfers (regression for the
    ``float(r.n_samples)`` / Python-sum aggregation of the old Server);
  * stateless sampling in both modes (the replacement branch used to call
    ``rng.choice(..., replace=False)`` and crash when per_round > K);
  * the full ServerState round-trips through repro.checkpoint and a
    stopped+resumed run reproduces the uninterrupted run exactly.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_pytree, save_pytree
from repro.configs.base import FederatedConfig
from repro.data import make_federated_features
from repro.data.pipeline import PackedCohort, pack_cohort_batches
from repro.federated.algorithms import (
    make_algorithm,
    server_init,
    server_state_from_tree,
)
from repro.federated.fed3r_driver import feature_finetune_task
from repro.federated.round_engine import ReferenceLoop, RoundConfig, RoundEngine
from repro.federated.sampling import ClientSampler, sample_round
from repro.federated.simulator import linear_head_task, pack_round, run_federated

N_CLIENTS, C, D = 12, 4, 8


@pytest.fixture(scope="module")
def fed_data():
    return make_federated_features(
        seed=0, n=600, d=D, n_classes=C, n_clients=N_CLIENTS, alpha=0.0, noise=1.5
    )


def _fc(**kw):
    base = dict(
        n_clients=N_CLIENTS, clients_per_round=4, n_rounds=3, local_epochs=1,
        local_batch_size=16, client_lr=0.1, algorithm="fedavg", seed=0,
    )
    base.update(kw)
    return FederatedConfig(**base)


def _rc(algo_name, **kw):
    algo = make_algorithm(algo_name, server_momentum=0.9 if algo_name == "fedavgm" else 0.0)
    base = dict(algo=algo, client_lr=0.1, n_total_clients=N_CLIENTS)
    base.update(kw)
    return RoundConfig(**base)


def _run_both(task, fed, rc, n_rounds=3, fc=None):
    fc = fc or _fc()
    eng = RoundEngine(rc, task.per_example_loss, task.freeze)
    ref = ReferenceLoop(rc, task.per_example_loss, task.freeze)
    se, sr = eng.init(task.params0), ref.init(task.params0)
    for rnd in range(n_rounds):
        _, cohort = pack_round(fed, fc, rnd, n_batches=4)
        se = eng.step(se, cohort)
        sr = ref.step(sr, cohort)
    return eng, ref, se, sr


# ---------------------------------------------------------------------------
# engine vs per-client reference loop — parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("algo", ["fedavg", "fedprox", "scaffold"])
def test_round_engine_matches_reference_loop(fed_data, algo):
    fed, test = fed_data
    task = linear_head_task(D, C, test.features, test.labels)
    eng, ref, se, sr = _run_both(task, fed, _rc(algo))
    for k in ("W", "bias"):
        np.testing.assert_allclose(
            np.asarray(se.params[k]), np.asarray(sr.params[k]),
            rtol=1e-5, atol=1e-6,
        )
    assert int(se.round) == int(sr.round) == 3
    # dispatch economics: 1 per round vs K+1 per round
    assert eng.dispatches == 3
    assert ref.dispatches == 3 * (4 + 1)


def test_round_engine_scaffold_cvar_state_matches_reference(fed_data):
    fed, test = fed_data
    task = linear_head_task(D, C, test.features, test.labels)
    _, _, se, sr = _run_both(task, fed, _rc("scaffold"))
    for a, b in zip(jax.tree.leaves(se.cvars), jax.tree.leaves(sr.cvars)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)
    for a, b in zip(jax.tree.leaves(se.c_server), jax.tree.leaves(sr.c_server)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)
    # only the sampled rows of the stacked table moved
    sampled = set()
    for rnd in range(3):
        sampled.update(int(k) for k in sample_round(N_CLIENTS, 4, rnd, seed=0))
    w_cvar = np.asarray(se.cvars["W"])
    for k in range(N_CLIENTS):
        if k not in sampled:
            assert not w_cvar[k].any()


@pytest.mark.parametrize("algo", ["fedavgm", "fedadam", "fedyogi"])
def test_round_engine_server_optimizers_match_reference(fed_data, algo):
    fed, test = fed_data
    task = linear_head_task(D, C, test.features, test.labels)
    rc = _rc(algo, server_lr=0.01 if algo in ("fedadam", "fedyogi") else 1.0)
    _, _, se, sr = _run_both(task, fed, rc)
    np.testing.assert_allclose(np.asarray(se.params["W"]), np.asarray(sr.params["W"]),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# freeze-mask semantics (FED3R+FT strategies)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("strategy,frozen,trainable", [
    ("full", (), ("M", "W", "bias")),
    ("lp", ("M",), ("W", "bias")),
    ("feat", ("W", "bias"), ("M",)),
])
def test_freeze_strategies(fed_data, strategy, frozen, trainable):
    fed, test = fed_data
    W0 = 0.01 * jax.random.normal(jax.random.PRNGKey(1), (D, C))
    task = feature_finetune_task(D, C, W0, test.features, test.labels,
                                 strategy=strategy)
    eng = RoundEngine(_rc("fedavg"), task.per_example_loss, task.freeze)
    state = eng.init(task.params0)
    for rnd in range(2):
        _, cohort = pack_round(fed, _fc(), rnd, n_batches=4)
        state = eng.step(state, cohort)
    for k in frozen:
        np.testing.assert_array_equal(
            np.asarray(state.params[k]), np.asarray(task.params0[k])
        )
    for k in trainable:
        assert not np.array_equal(
            np.asarray(state.params[k]), np.asarray(task.params0[k])
        )


# ---------------------------------------------------------------------------
# cohort permutation invariance (bitwise)
# ---------------------------------------------------------------------------


def test_round_invariant_under_cohort_permutation(fed_data):
    fed, test = fed_data
    task = linear_head_task(D, C, test.features, test.labels)
    ids = [7, 2, 11, 5]
    clients = [(fed.client(k).features, fed.client(k).labels) for k in ids]
    p1 = pack_cohort_batches(clients, 16, 4, client_ids=ids, seed=(0, 0))
    perm = [2, 0, 3, 1]
    p2 = pack_cohort_batches(
        [clients[i] for i in perm], 16, 4,
        client_ids=[ids[i] for i in perm], seed=(0, 0),
    )
    for a, b in zip(p1, p2):  # identical packed arrays...
        np.testing.assert_array_equal(a, b)
    eng = RoundEngine(_rc("fedavg"), task.per_example_loss, task.freeze)
    s1 = eng.step(eng.init(task.params0), p1)
    s2 = eng.step(eng.init(task.params0), p2)
    # ...hence a bit-identical aggregated round
    np.testing.assert_array_equal(np.asarray(s1.params["W"]), np.asarray(s2.params["W"]))
    np.testing.assert_array_equal(np.asarray(s1.params["bias"]), np.asarray(s2.params["bias"]))


def test_padded_cohort_slots_are_noops(fed_data):
    fed, test = fed_data
    task = linear_head_task(D, C, test.features, test.labels)
    ids = [3, 8]
    clients = [(fed.client(k).features, fed.client(k).labels) for k in ids]
    tight = pack_cohort_batches(clients, 16, 4, client_ids=ids, seed=(0, 0))
    padded = pack_cohort_batches(clients, 16, 4, client_ids=ids, seed=(0, 0),
                                 cohort_size=5)
    assert padded.cohort == 5 and padded.n_clients == 2
    for algo in ("fedavg", "scaffold"):
        eng = RoundEngine(_rc(algo), task.per_example_loss, task.freeze)
        s1 = eng.step(eng.init(task.params0), tight)
        s2 = eng.step(eng.init(task.params0), padded)
        np.testing.assert_allclose(np.asarray(s1.params["W"]),
                                   np.asarray(s2.params["W"]), rtol=1e-6, atol=1e-7)
        if algo == "scaffold":
            np.testing.assert_allclose(
                np.asarray(s1.c_server["W"]), np.asarray(s2.c_server["W"]),
                rtol=1e-6, atol=1e-7,
            )


# ---------------------------------------------------------------------------
# hot path is transfer-free (regression: float()/Python-sum aggregation)
# ---------------------------------------------------------------------------


def test_round_step_hot_path_makes_no_host_transfers(fed_data):
    fed, test = fed_data
    task = linear_head_task(D, C, test.features, test.labels)
    eng = RoundEngine(_rc("scaffold"), task.per_example_loss, task.freeze)
    _, cohort = pack_round(fed, _fc(), 0, n_batches=4)
    dev_cohort = PackedCohort(*[jnp.asarray(a) for a in cohort])
    state = eng.step(eng.init(task.params0), dev_cohort)  # warm the trace
    # steady-state rounds: everything already on device ⇒ zero transfers
    with jax.transfer_guard("disallow"):
        state = eng.step(state, dev_cohort)
        state = eng.step(state, dev_cohort)
    assert int(state.round) == 3


# ---------------------------------------------------------------------------
# sampling: both modes, statelessness
# ---------------------------------------------------------------------------


def test_sampler_with_replacement_honors_the_flag():
    # regression: this mode used to call rng.choice(..., replace=False)
    draws = [sample_round(5, 64, r, seed=0, replacement=True) for r in range(4)]
    for d in draws:
        assert len(d) == 64  # per_round > n_clients is legal with replacement
    # iid draws: some round contains a duplicate with overwhelming probability
    assert any(len(np.unique(d)) < len(d) for d in draws)


def test_sampler_without_replacement_epoch_exactness():
    per_epoch = []
    for rnd in range(6):  # 6 rounds × 4 = 2 epochs over 12 clients
        per_epoch.extend(sample_round(12, 4, rnd, seed=3).tolist())
    assert sorted(per_epoch[:12]) == list(range(12))  # epoch 1 exact
    assert sorted(per_epoch[12:]) == list(range(12))  # epoch 2 exact
    assert per_epoch[:12] != list(range(12))  # and actually shuffled


def test_sample_round_is_stateless_and_sampler_delegates():
    for replacement in (False, True):
        a = [sample_round(10, 3, r, seed=1, replacement=replacement) for r in range(5)]
        b = [sample_round(10, 3, r, seed=1, replacement=replacement) for r in range(5)]
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x, y)
        s = ClientSampler(10, 3, replacement=replacement, seed=1)
        for x in a:
            np.testing.assert_array_equal(x, s.sample())
    assert ClientSampler(17, 5).rounds_to_full_coverage() == 4


# ---------------------------------------------------------------------------
# ServerState checkpointing + stop/resume equivalence
# ---------------------------------------------------------------------------


def test_server_state_checkpoint_roundtrip(tmp_path):
    params = {"W": jnp.ones((3, 2)), "bias": jnp.zeros((2,))}
    state = server_init(make_algorithm("scaffold"), params, n_clients=5)
    state = state._replace(round=jnp.asarray(4, jnp.int32))
    path = os.path.join(tmp_path, "ckpt_4.npz")
    save_pytree(path, state)
    back = server_state_from_tree(load_pytree(path))
    assert int(back.round) == 4
    assert back.momentum is None and back.opt_m is None  # Nones survive
    assert back.cvars["W"].shape == (5, 3, 2)
    np.testing.assert_array_equal(np.asarray(state.params["W"]), back.params["W"])


@pytest.mark.parametrize("algo", ["fedavg", "scaffold", "fedadam"])
def test_stop_resume_reproduces_uninterrupted_run(fed_data, tmp_path, algo):
    fed, test = fed_data
    kw = dict(algorithm=algo, n_rounds=6,
              server_lr=0.01 if algo == "fedadam" else 1.0)
    task = linear_head_task(D, C, test.features, test.labels)
    straight, _ = run_federated(task, fed, _fc(**kw), eval_every=3)

    ckpt = str(tmp_path / algo)
    task2 = linear_head_task(D, C, test.features, test.labels)
    run_federated(task2, fed, _fc(**{**kw, "n_rounds": 3}), eval_every=3,
                  ckpt_dir=ckpt)
    task3 = linear_head_task(D, C, test.features, test.labels)
    resumed, _ = run_federated(task3, fed, _fc(**kw), eval_every=3,
                               ckpt_dir=ckpt, resume=True)
    np.testing.assert_array_equal(np.asarray(straight["W"]), np.asarray(resumed["W"]))
    np.testing.assert_array_equal(np.asarray(straight["bias"]), np.asarray(resumed["bias"]))


# ---------------------------------------------------------------------------
# mesh mode: psum backend == merge backend
# ---------------------------------------------------------------------------


def test_round_engine_psum_matches_merge_on_host_mesh(fed_data):
    """The dist-layer mesh path (shard_map owned by DistContext) == merge."""
    from repro.federated.dist import DistConfig
    from repro.launch.mesh import make_host_mesh

    fed, test = fed_data
    task = linear_head_task(D, C, test.features, test.labels)
    mesh = make_host_mesh()
    _, cohort = pack_round(fed, _fc(), 0, n_batches=4)  # cohort of 4
    # same cohort, padded so the cohort axis divides the data-parallel size
    _, cohort_dp = pack_round(fed, _fc(), 0, n_batches=4, mesh=mesh)

    merge_eng = RoundEngine(_rc("fedavg"), task.per_example_loss, task.freeze)
    ref = merge_eng.step(merge_eng.init(task.params0), cohort)

    psum_eng = RoundEngine(
        _rc("fedavg", dist=DistConfig(aggregation="psum", mesh=mesh, donate=False)),
        task.per_example_loss, task.freeze,
    )
    got = psum_eng.step(psum_eng.init(task.params0), cohort_dp)
    assert psum_eng.dispatches == 1  # the shard_map program is ONE dispatch
    np.testing.assert_allclose(np.asarray(ref.params["W"]), np.asarray(got.params["W"]),
                               rtol=1e-5, atol=1e-6)


def test_psum_config_validation(fed_data):
    from repro.federated.dist import DistConfig

    fed, test = fed_data
    task = linear_head_task(D, C, test.features, test.labels)
    with pytest.raises(ValueError):
        DistConfig(aggregation="psum")  # no axes, no mesh
    with pytest.raises(ValueError):
        DistConfig(aggregation="allgather")
    with pytest.raises(ValueError):  # scaffold cvar scatter needs the cohort
        RoundEngine(
            _rc("scaffold", dist=DistConfig(aggregation="psum", mesh_axes=("data",))),
            task.per_example_loss, task.freeze,
        )
